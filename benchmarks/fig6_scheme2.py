"""Paper Fig. 6: EmuGEMM-II vs the GEMMul8-class unfused reference.

GEMMul8's structure = per-modulus GEMM kernel + separate modular-reduction
kernel, INT32 products materialized between them (the library the paper
improves on). Our 'fused' structure performs the reduction in the same
compiled program. Real DGEMM (x64) and complex ZGEMM via 3M, matched
p in {6, 9, 12, 15}.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import complex3m, scheme2
from repro.core.precision import EmulationConfig, default_moduli, \
    scheme2_budget

from benchmarks.common import (bits_of_precision, conditioned, csv_row,
                               effective_tflops, time_fn)


def gemmul8_class_naive(a, b, moduli, out_dtype):
    """Unfused Scheme II: one dispatch per residue GEMM, one per modular
    reduction, INT32 materialized in between (paper Eq. 14 traffic)."""
    k = a.shape[-1]
    budget = min(scheme2_budget(moduli, k), jnp.finfo(a.dtype).nmant + 1)
    prep = jax.jit(lambda a, b: _prep(a, b, moduli, budget))
    a_res, b_res, mu, nu = prep(a, b)
    dot = jax.jit(lambda x, y: jax.lax.dot_general(
        x, y, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32))
    reduce_l = [jax.jit(lambda x, m=int(m): jnp.remainder(x, m)) for m in
                moduli]
    residues = []
    for l in range(len(moduli)):
        acc = dot(a_res[l], b_res[l])
        jax.block_until_ready(acc)          # INT32 round-trip
        r = reduce_l[l](acc)
        jax.block_until_ready(r)
        residues.append(r)
    rec = jax.jit(lambda rs, mu, nu: scheme2.crt_reconstruct(
        jnp.stack(rs), moduli, out_dtype) / (mu.astype(out_dtype)
                                             * nu.astype(out_dtype)))
    return rec(residues, mu, nu)


def _prep(a, b, moduli, budget):
    a_int, mu = scheme2.integerize(a, axis=1, budget_bits=budget)
    b_int, nu = scheme2.integerize(b, axis=0, budget_bits=budget)
    return (scheme2.balanced_residues(a_int, moduli),
            scheme2.balanced_residues(b_int, moduli), mu, nu)


def main(quick: bool = True):
    rng = np.random.default_rng(2)
    sizes = (256,) if quick else (256, 512, 1024)
    rows = []
    with jax.experimental.enable_x64():
        for n in sizes:
            a = conditioned(rng, (n, n), dtype=np.float64)
            b = conditioned(rng, (n, n), dtype=np.float64)
            ref = a.astype(np.longdouble) @ b.astype(np.longdouble)
            aj, bj = jnp.asarray(a), jnp.asarray(b)
            natf64 = jax.jit(lambda x, y: x @ y)
            t64 = time_fn(natf64, aj, bj)
            csv_row("fig6_native_dgemm", t64 * 1e6,
                    f"N={n};tflops={effective_tflops(n, t64):.3f}")
            for p in (6, 9, 12, 15):
                cfg = EmulationConfig(scheme="ozaki2", p=p)
                fused = jax.jit(lambda x, y, cfg=cfg: scheme2.matmul(
                    x, y, cfg, jnp.float64))
                t_f = time_fn(fused, aj, bj)
                out = np.asarray(fused(aj, bj)).astype(np.longdouble)
                bits = bits_of_precision(out, ref)
                moduli = default_moduli(p)
                t_n = time_fn(
                    lambda x, y: gemmul8_class_naive(x, y, moduli,
                                                     jnp.float64),
                    aj, bj, iters=3, warmup=1)
                csv_row(f"fig6_dgemm_p{p}", t_f * 1e6,
                        f"N={n};bits={bits:.1f};"
                        f"fused_vs_naive={t_n / t_f:.2f}x;"
                        f"vs_native_f64={t64 / t_f:.2f}x")
                rows.append((n, p, bits, t_n / t_f))
    return rows


if __name__ == "__main__":
    main(quick=False)
