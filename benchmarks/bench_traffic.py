"""Decomposition/residue-side HBM traffic: the XLA reference pipelines
vs the fused prologues vs prepared-weight reuse.

Seeds the bench trajectory with a deterministic, interpret-mode-safe
metric: the analytic byte models (repro.core.traffic
.scheme{1,2}_decomp_*_bytes, surfaced through repro.utils.roofline
.scheme{1,2}_decomposition_terms), corroborated by measured
compiled-HLO bytes/op-counts of the XLA-visible stages, and bit-identity
checks of the fused kernels against their XLA oracles — the Scheme-I
prologue vs the split -> interleave -> kernel pipeline, and the fused
GPU Scheme-II / complex-3M residue pipeline vs ``scheme2.matmul`` /
``complex3m.matmul`` (including the PreparedResidues rhs variant).

  PYTHONPATH=src python benchmarks/bench_traffic.py \
      [--out BENCH_traffic.json] [--check-baseline benchmarks/traffic_baseline.json]

With --check-baseline the run exits non-zero if any cell's bytes regress
above the recorded baseline or the headline reductions fall below the
acceptance floors (>=2x fused prologue, >=3x PreparedOperand weight
reuse at p=4; >= p-fold fused residue-side reduction for Scheme II at
m=6) — the CI regression gate.

The sharded cell family reports the shard_map'ed fused GEMM (repro
.parallel.shard_gemm) on two 8-device mesh layouts: per-shard fused
decomposition bytes next to the collective bytes each tensor-parallel
partitioning adds (column must stay collective-free; row pays a ring
all-reduce of the output partials), with the roofline-effective Top/s
per gpu hardware table.
"""

from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import scheme1, traffic  # noqa: E402
from repro.core.precision import EmulationConfig  # noqa: E402
from repro.utils import roofline  # noqa: E402

SHAPES = [(256, 256, 256), (128, 384, 256), (256, 128, 512)]  # (M, K, N)
PS = (3, 4, 6)
USES = 3  # forward, remat re-forward, backward B^T — per layer per step
PROLOGUE_FLOOR = 2.0
PREPARED_FLOOR = 3.0

# Scheme-II cells: output-heavy shapes (the residue win is the (p, M, N)
# int32/canonical round-trips the fused epilogue keeps on-chip).
SCHEME2_SHAPES = [(256, 256, 256), (256, 128, 256), (192, 128, 384)]
MS = (4, 6)                    # moduli counts
SCHEME2_FLOOR = 6.0            # >= p-fold fused reduction at m=6

# Guard cells: modeled a-posteriori-verification overhead of a guarded
# fused GEMM (traffic.guard_overhead_model, docs/robustness.md).  The
# fused piggyback model is gated at <= 5% of the GEMM's bytes AND
# roofline time on every benchmarked shape; the unfused (XLA reference)
# verify bytes are reported alongside, ungated.
GUARD_SHAPES = SHAPES + SCHEME2_SHAPES[1:]
GUARD_PROBES = 2
GUARD_OVERHEAD_CEILING = 0.05

# Telemetry cells: modeled observability overhead of an instrumented
# fused GEMM (traffic.telemetry_overhead_model, docs/observability.md).
# The per-call debug-callback payload is tens of bytes, so the gate is
# tighter than the guard's: <= 2% of the GEMM's bytes AND roofline time
# on every benchmarked shape.  The cells also assert the disabled-mode
# contract: with telemetry off, jaxprs carry no debug callbacks and the
# emulated outputs are bit-identical to the enabled run.
TELEMETRY_SHAPES = GUARD_SHAPES
TELEMETRY_OVERHEAD_CEILING = 0.02

# Decode-step cells: per-token serving traffic of one decode GEMM
# x(B, K) @ W(K, N) (traffic.scheme1_decode_*, docs/serving.md).  The
# prepared weight stream is batch-invariant, so per-token bytes fall
# ~linearly with the decode batch — the analytic case for the
# continuous-batching engine keeping its lanes full.  Gated: batch-32
# amortization >= 24x over batch 1, and the prepared stream beats the
# per-step XLA re-decomposition >= 4x at every batch.
DECODE_SHAPES = [(2048, 2048), (2048, 8192), (4096, 4096)]  # (K, N)
DECODE_BATCHES = (1, 8, 32)
DECODE_P = 4
DECODE_AMORTIZATION_FLOOR = 24.0
DECODE_PREPARED_FLOOR = 4.0

# Shard_map'ed cells: per-shard fused decomposition bytes next to the
# collective bytes each mesh layout adds (repro.parallel.shard_gemm
# partitioning; analytic models in traffic.sharded_gemm_traffic).
SHARDED_SHAPES = [(256, 256, 512), (512, 384, 1024), (256, 512, 2048)]
MESH_LAYOUTS = [(("data", 1), ("model", 8)), (("data", 2), ("model", 4))]
SHARDED_P = 4

# Strided-batched cells: one (B, bM, bN)-grid fused launch vs the vmap
# fallback's B per-element launches (traffic.scheme{1,2}_batched_bytes;
# dispatch.emulated_matmul_batched).  Gated: every cell must show a
# >= B-fold launch reduction and a >= 2x modeled decomposition-byte
# reduction over the vmap route; the verify cell checks the batched
# fused kernels bitwise against the vmapped 2-D reference (interpret
# mode), both schemes.
BATCHED_BS = (4, 16)
BATCHED_SCHEMES = (("ozaki1", 4), ("ozaki2", 6))  # (scheme, p-or-moduli)
BATCHED_DECOMP_FLOOR = 2.0


def _count_ops(hlo_text: str) -> int:
    return sum(1 for line in hlo_text.splitlines()
               if roofline._OP_RE.match(line))


def _measure(fn, *args) -> dict:
    """Compiled-HLO mem bytes + op count of a jitted stage (roofline path)."""
    text = jax.jit(fn).lower(*args).compile().as_text()
    stats = roofline.analyze_hlo(text)
    return {"mem_bytes": int(stats["mem_bytes"]), "ops": _count_ops(text)}


def _bit_identity(m: int, k: int, n: int, p: int) -> bool:
    """Prologue output must equal the split->interleave pipeline bitwise
    (same int8 slices -> same int32 accumulators -> same epilogue)."""
    from repro.kernels import ops
    rng = np.random.default_rng(p * 7919 + m + k + n)
    a = jnp.asarray(((rng.random((m, k)) - 0.5)
                     * np.exp(2.0 * rng.standard_normal((m, k))))
                    .astype(np.float32))
    b = jnp.asarray(((rng.random((k, n)) - 0.5)
                     * np.exp(2.0 * rng.standard_normal((k, n))))
                    .astype(np.float32))
    pro = ops.fused_scheme1_matmul(
        a, b, EmulationConfig(scheme="ozaki1", p=p, decomp="kernel"))
    xla = ops.fused_scheme1_matmul(
        a, b, EmulationConfig(scheme="ozaki1", p=p, decomp="xla"))
    return bool(jnp.array_equal(pro, xla))


def run_cell(m: int, k: int, n: int, p: int, verify: bool) -> dict:
    terms = roofline.scheme1_decomposition_terms(m, k, n, p, uses=USES)
    w = k * n  # the weight (rhs) operand
    weight = {
        "xla": traffic.scheme1_decomp_xla_bytes(w, p, USES),
        "prepared": traffic.scheme1_decomp_prepared_bytes(w, p, 1),
    }
    cell = {
        "m": m, "k": k, "n": n, "p": p,
        "decomp_bytes": {
            "xla": terms["xla_bytes"],
            "prologue": terms["prologue_bytes"],
            "prepared": terms["prepared_bytes"],
        },
        "weight_decomp_bytes": weight,
        "reduction": {
            "prologue": terms["xla_bytes"] / terms["prologue_bytes"],
            "prepared": terms["xla_bytes"] / terms["prepared_bytes"],
            "prepared_weight": weight["xla"] / weight["prepared"],
        },
    }

    beta = EmulationConfig(scheme="ozaki1", p=p).resolved_beta(k)

    def xla_stage(a, b):
        a_sl, mu = scheme1.split(a, p, beta, axis=1)
        b_sl, nu = scheme1.split(b, p, beta, axis=0)
        return (scheme1.interleave_k(a_sl, "a", 128),
                scheme1.interleave_k(b_sl, "b", 128), mu, nu)

    def prologue_stage(a, b):
        # Only the scale reductions stay in XLA on the prologue path.
        return (scheme1._pow2_row_scale(a, axis=1),
                scheme1._pow2_row_scale(b, axis=0))

    a_spec = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b_spec = jax.ShapeDtypeStruct((k, n), jnp.float32)
    cell["measured"] = {
        "xla_stage": _measure(xla_stage, a_spec, b_spec),
        "prologue_stage": _measure(prologue_stage, a_spec, b_spec),
    }
    # Per-backend roofline projection (paper Fig. 4/5 framing): fraction
    # of INT8 peak per hardware table of each registered kernel backend —
    # the 'gpu' entry carries both Hopper (H100) and Blackwell (B200).
    cell["projection"] = {
        bk: roofline.projected_throughput(m, k, n, p, backend=bk)
        for bk in ("tpu", "gpu")
    }
    if verify:
        cell["bit_identical"] = _bit_identity(m, k, n, p)
    return cell


def _bit_identity_scheme2(m: int, k: int, n: int, p: int) -> dict:
    """Fused GPU Scheme-II (real, complex-3M, prepared rhs) must equal
    the scheme2.matmul / complex3m.matmul references bitwise."""
    from repro.core import complex3m, scheme2
    from repro.kernels import dispatch, prepared
    rng = np.random.default_rng(p * 6997 + m + k + n)

    def cond(shape):
        return jnp.asarray(((rng.random(shape) - 0.5)
                            * np.exp(2.0 * rng.standard_normal(shape)))
                           .astype(np.float32))

    cfg = EmulationConfig(scheme="ozaki2", p=p, backend="gpu")
    a, b = cond((m, k)), cond((k, n))
    fused = dispatch.emulated_matmul(a, b, cfg=cfg)
    oracle = scheme2.matmul(a, b, cfg, jnp.float32)
    prep = prepared.prepare_rhs(b, cfg)
    prepped = dispatch.emulated_matmul(a, prep, cfg=cfg)
    ac = (cond((m, k)) + 1j * cond((m, k))).astype(jnp.complex64)
    bc = (cond((k, n)) + 1j * cond((k, n))).astype(jnp.complex64)
    fused_c = dispatch.emulated_matmul(ac, bc, cfg=cfg,
                                       out_dtype=jnp.complex64)
    oracle_c = complex3m.matmul(ac, bc, cfg, jnp.float32)
    return {
        "real": bool(jnp.array_equal(fused, oracle)),
        "prepared": bool(jnp.array_equal(prepped, oracle)),
        "complex_3m": bool(jnp.array_equal(fused_c, oracle_c)),
    }


def run_scheme2_cell(m: int, k: int, n: int, p: int, verify: bool) -> dict:
    terms = roofline.scheme2_decomposition_terms(m, k, n, p, uses=USES)
    terms_3m = roofline.scheme2_decomposition_terms(m, k, n, p, uses=USES,
                                                    complex_3m=True)
    cell = {
        "m": m, "k": k, "n": n, "p": p,
        "decomp_bytes": {
            "xla": terms["xla_bytes"],
            "prologue": terms["prologue_bytes"],
            "prepared": terms["prepared_bytes"],
        },
        "decomp_bytes_3m": {
            "xla": terms_3m["xla_bytes"],
            "prologue": terms_3m["prologue_bytes"],
            "prepared": terms_3m["prepared_bytes"],
        },
        "reduction": {
            "prologue": terms["xla_bytes"] / terms["prologue_bytes"],
            "prepared": terms["xla_bytes"] / terms["prepared_bytes"],
            "prologue_3m":
                terms_3m["xla_bytes"] / terms_3m["prologue_bytes"],
        },
        # Paper Sec. V framing: projected Top/s + speedup over the FP64
        # BLAS baseline (DGEMM real / ZGEMM complex) per gpu hardware.
        "projection": {
            "real": roofline.projected_throughput(
                m, k, n, p, scheme="ozaki2", backend="gpu"),
            "complex_3m": roofline.projected_throughput(
                m, k, n, p, scheme="ozaki2", backend="gpu",
                complex_3m=True),
        },
    }
    if verify:
        cell["bit_identical"] = _bit_identity_scheme2(m, k, n, p)
    return cell


def run_guard_cell(m: int, k: int, n: int) -> dict:
    """Modeled verification overhead for both schemes on one shape."""
    s = traffic.GemmShape(m, n, k)
    cell = {"m": m, "k": k, "n": n, "probes": GUARD_PROBES, "schemes": {}}
    for scheme, p in (("ozaki1", 4), ("ozaki2", 6)):
        cell["schemes"][scheme] = dict(
            traffic.guard_overhead_model(s, p, scheme, probes=GUARD_PROBES),
            p=p)
    return cell


def run_telemetry_cell(m: int, k: int, n: int) -> dict:
    """Modeled telemetry overhead for both schemes on one shape."""
    s = traffic.GemmShape(m, n, k)
    cell = {"m": m, "k": k, "n": n, "schemes": {}}
    for scheme, p in (("ozaki1", 4), ("ozaki2", 6)):
        cell["schemes"][scheme] = dict(
            traffic.telemetry_overhead_model(s, p, scheme), p=p)
    return cell


def telemetry_disabled_checks() -> dict:
    """Disabled-mode contract of repro.telemetry: no debug callbacks in
    the jaxpr, and bit-identical outputs enabled vs disabled."""
    from repro import telemetry
    from repro.kernels import dispatch
    rng = np.random.default_rng(4242)
    a = jnp.asarray(rng.standard_normal((128, 128)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((128, 128)).astype(np.float32))
    cfg = EmulationConfig(scheme="ozaki1", p=3)

    # A fresh closure per trace: JAX's tracing cache keys on function
    # identity, so re-tracing one ``f`` after flipping the telemetry
    # flag would silently replay the first jaxpr.
    def make_f():
        def f(a, b):
            return dispatch.emulated_matmul(a, b, cfg=cfg)
        return f

    was = telemetry.enabled()
    try:
        telemetry.disable()
        jaxpr_off = str(jax.make_jaxpr(make_f())(a, b))
        out_off = make_f()(a, b)
        telemetry.enable()
        jaxpr_on = str(jax.make_jaxpr(make_f())(a, b))
        out_on = make_f()(a, b)
    finally:
        (telemetry.enable if was else telemetry.disable)()
    return {
        "callback_free_disabled": "debug_callback" not in jaxpr_off,
        "callback_present_enabled": "debug_callback" in jaxpr_on,
        "bit_identical": bool(jnp.array_equal(out_off, out_on)),
    }


def run_decode_cell(k: int, n: int, p: int) -> dict:
    """Per-token decode-step bytes for one (K, N) projection weight at
    each serving batch size, per weight-decomposition path."""
    cell = {"k": k, "n": n, "p": p, "batches": {}}
    for b in DECODE_BATCHES:
        cell["batches"][str(b)] = {
            "per_token_bytes": {
                path: traffic.scheme1_decode_per_token_bytes(
                    k, n, b, p, path)
                for path in ("prepared", "prologue", "xla")},
            "step_bytes_prepared":
                traffic.scheme1_decode_step_bytes(k, n, b, p, "prepared"),
        }
    cell["amortization"] = {
        str(b): traffic.decode_batch_amortization(k, n, p, b)
        for b in DECODE_BATCHES}
    cell["prepared_vs_xla"] = {
        str(b): (cell["batches"][str(b)]["per_token_bytes"]["xla"]
                 / cell["batches"][str(b)]["per_token_bytes"]["prepared"])
        for b in DECODE_BATCHES}
    return cell


def run_sharded_cell(m: int, k: int, n: int, p: int, layout) -> dict:
    """Per-shard fused bytes + collective bytes of one shard_map'ed GEMM
    on one mesh layout, under both tensor-parallel partitionings."""
    s = traffic.GemmShape(m, n, k)
    cell = {"m": m, "k": k, "n": n, "p": p,
            "mesh": {a: sz for a, sz in layout}, "partitions": {}}
    for part in ("column", "row"):
        t = traffic.sharded_gemm_traffic(s, p, layout, part)
        proj = roofline.sharded_projected_throughput(m, k, n, p, layout,
                                                     part)
        cell["partitions"][part] = {
            "shard_shape": [t["shard_m"], t["shard_k"], t["shard_n"]],
            "fused_bytes_per_shard": t["fused_bytes_per_shard"],
            "collective_bytes_per_device": t["collective_bytes_per_device"],
            "collective_s": proj["collective_s"],
            "effective_tops": {
                hw: c["effective_tops"]
                for hw, c in proj["hardware"].items()},
        }
    return cell


def _bit_identity_batched(scheme: str, p: int) -> bool:
    """The strided-batched fused lowering must match the vmapped 2-D
    fused reference bitwise (same scales, same kernel body per tile)."""
    from repro.kernels import dispatch
    rng = np.random.default_rng(7237 * p + (1 if scheme == "ozaki2" else 0))
    batch, m, k, n = 4, 64, 96, 128

    def cond(shape):
        return jnp.asarray(((rng.random(shape) - 0.5)
                            * np.exp(2.0 * rng.standard_normal(shape)))
                           .astype(np.float32))

    a, b = cond((batch, m, k)), cond((batch, k, n))
    cfg = EmulationConfig(scheme=scheme, p=p, backend="gpu")
    fused = dispatch.emulated_matmul_batched(a, b, cfg=cfg)
    ref = jax.vmap(lambda x, y: dispatch.emulated_matmul(x, y, cfg=cfg))(a, b)
    return bool(jnp.array_equal(fused, ref))


def run_batched_cell(m: int, k: int, n: int, scheme: str, p: int,
                     batch: int) -> dict:
    """Modeled launch counts + HBM bytes of one B-stack, fused vs vmap,
    with the roofline projection columns for both routes."""
    s = traffic.GemmShape(m, n, k)
    model = (traffic.scheme1_batched_bytes if scheme == "ozaki1"
             else traffic.scheme2_batched_bytes)(s, p, batch)
    return {
        "m": m, "k": k, "n": n, "p": p, "scheme": scheme, "batch": batch,
        "paths": model,
        "launch_reduction":
            model["vmap"]["launches"] / model["fused"]["launches"],
        "decomp_reduction":
            model["vmap"]["decomp_bytes"] / model["fused"]["decomp_bytes"],
        "projection": roofline.batched_projected_throughput(
            m, k, n, batch, p, scheme=scheme, backend="gpu"),
    }


def check_baseline(report: dict, baseline: dict) -> list[str]:
    errors = []
    base = {(c["m"], c["k"], c["n"], c["p"]): c for c in baseline["cells"]}
    for c in report["cells"]:
        key = (c["m"], c["k"], c["n"], c["p"])
        ref = base.get(key)
        if ref is None:
            continue
        for path, cur in c["decomp_bytes"].items():
            old = ref["decomp_bytes"].get(path)
            if old is not None and cur > old:
                errors.append(f"{key} {path}: {cur} > baseline {old}")
        if c.get("bit_identical") is False:
            errors.append(f"{key}: prologue not bit-identical to split")
    base2 = {(c["m"], c["k"], c["n"], c["p"]): c
             for c in baseline.get("scheme2_cells", ())}
    for c in report.get("scheme2_cells", ()):
        key = (c["m"], c["k"], c["n"], c["p"])
        ref = base2.get(key)
        if ref is not None:
            for field in ("decomp_bytes", "decomp_bytes_3m"):
                for path, cur in c[field].items():
                    old = ref[field].get(path)
                    if old is not None and cur > old:
                        errors.append(
                            f"scheme2 {key} {field}/{path}: {cur} > "
                            f"baseline {old}")
        for variant, ok in c.get("bit_identical", {}).items():
            if ok is False:
                errors.append(f"scheme2 {key}: fused {variant} path not "
                              "bit-identical to the reference")
    base_sh = {(c["m"], c["k"], c["n"], c["p"],
                tuple(sorted(c["mesh"].items()))): c
               for c in baseline.get("sharded_cells", ())}
    for c in report.get("sharded_cells", ()):
        key = (c["m"], c["k"], c["n"], c["p"],
               tuple(sorted(c["mesh"].items())))
        ref = base_sh.get(key)
        for part, cur in c["partitions"].items():
            if cur["collective_bytes_per_device"] and part == "column":
                errors.append(f"sharded {key}: column layout grew a "
                              "collective")
            if ref is None or part not in ref["partitions"]:
                continue
            old = ref["partitions"][part]
            for field in ("fused_bytes_per_shard",
                          "collective_bytes_per_device"):
                if cur[field] > old[field]:
                    errors.append(f"sharded {key} {part} {field}: "
                                  f"{cur[field]} > baseline {old[field]}")
    base_g = {(c["m"], c["k"], c["n"]): c
              for c in baseline.get("guard_cells", ())}
    for c in report.get("guard_cells", ()):
        key = (c["m"], c["k"], c["n"])
        ref = base_g.get(key)
        for scheme, cur in c["schemes"].items():
            for field in ("bytes_ratio", "time_ratio"):
                if cur[field] > GUARD_OVERHEAD_CEILING:
                    errors.append(
                        f"guard {key} {scheme}: {field} "
                        f"{cur[field]:.4f} > {GUARD_OVERHEAD_CEILING}")
            if ref is not None and scheme in ref["schemes"]:
                old = ref["schemes"][scheme]
                if cur["verify_bytes_fused"] > old["verify_bytes_fused"]:
                    errors.append(
                        f"guard {key} {scheme}: verify_bytes_fused "
                        f"{cur['verify_bytes_fused']} > baseline "
                        f"{old['verify_bytes_fused']}")
    base_t = {(c["m"], c["k"], c["n"]): c
              for c in baseline.get("telemetry_cells", ())}
    for c in report.get("telemetry_cells", ()):
        key = (c["m"], c["k"], c["n"])
        ref = base_t.get(key)
        for scheme, cur in c["schemes"].items():
            for field in ("bytes_ratio", "time_ratio"):
                if cur[field] > TELEMETRY_OVERHEAD_CEILING:
                    errors.append(
                        f"telemetry {key} {scheme}: {field} "
                        f"{cur[field]:.6f} > {TELEMETRY_OVERHEAD_CEILING}")
            if ref is not None and scheme in ref["schemes"]:
                old = ref["schemes"][scheme]
                if cur["telemetry_bytes"] > old["telemetry_bytes"]:
                    errors.append(
                        f"telemetry {key} {scheme}: telemetry_bytes "
                        f"{cur['telemetry_bytes']} > baseline "
                        f"{old['telemetry_bytes']}")
    base_b = {(c["m"], c["k"], c["n"], c["p"], c["scheme"], c["batch"]): c
              for c in baseline.get("batched_cells", ())}
    for c in report.get("batched_cells", ()):
        key = (c["m"], c["k"], c["n"], c["p"], c["scheme"], c["batch"])
        if c["launch_reduction"] < c["batch"]:
            errors.append(
                f"batched {key}: launch reduction "
                f"{c['launch_reduction']:.1f} < B={c['batch']}")
        if c["decomp_reduction"] < BATCHED_DECOMP_FLOOR:
            errors.append(
                f"batched {key}: decomp reduction "
                f"{c['decomp_reduction']:.2f} < {BATCHED_DECOMP_FLOOR}")
        if c.get("bit_identical") is False:
            errors.append(f"batched {key}: fused batched lowering not "
                          "bit-identical to the vmapped 2-D reference")
        ref = base_b.get(key)
        if ref is not None:
            for path in ("fused", "vmap"):
                cur = c["paths"][path]["total_bytes"]
                old = ref["paths"][path]["total_bytes"]
                if cur > old:
                    errors.append(f"batched {key} {path}: {cur} > "
                                  f"baseline {old}")
    base_d = {(c["k"], c["n"], c["p"]): c
              for c in baseline.get("decode_cells", ())}
    for c in report.get("decode_cells", ()):
        key = (c["k"], c["n"], c["p"])
        ref = base_d.get(key)
        if ref is None:
            continue
        for b, cur in c["batches"].items():
            old = ref["batches"].get(b)
            if old is None:
                continue
            for path, val in cur["per_token_bytes"].items():
                prev = old["per_token_bytes"].get(path)
                if prev is not None and val > prev:
                    errors.append(f"decode {key} b={b} {path}: "
                                  f"{val} > baseline {prev}")
    head = report["acceptance"]
    if head.get("decode_amortization_b32",
                DECODE_AMORTIZATION_FLOOR) < DECODE_AMORTIZATION_FLOOR:
        errors.append(
            f"decode amortization {head['decode_amortization_b32']:.2f} "
            f"< {DECODE_AMORTIZATION_FLOOR} at b={max(DECODE_BATCHES)}")
    if head.get("decode_prepared_vs_xla",
                DECODE_PREPARED_FLOOR) < DECODE_PREPARED_FLOOR:
        errors.append(
            f"decode prepared-vs-xla {head['decode_prepared_vs_xla']:.2f}"
            f" < {DECODE_PREPARED_FLOOR}")
    for field in ("telemetry_disabled_callback_free",
                  "telemetry_disabled_bit_identical"):
        if head.get(field) is False:
            errors.append(f"{field} is False: disabled-mode telemetry "
                          "contract broken")
    if head["prologue_reduction_p4"] < PROLOGUE_FLOOR:
        errors.append(f"prologue reduction {head['prologue_reduction_p4']:.2f}"
                      f" < {PROLOGUE_FLOOR}")
    if head["prepared_weight_reduction_p4"] < PREPARED_FLOOR:
        errors.append(
            f"prepared weight reduction "
            f"{head['prepared_weight_reduction_p4']:.2f} < {PREPARED_FLOOR}")
    if head.get("scheme2_fused_reduction_m6", SCHEME2_FLOOR) < SCHEME2_FLOOR:
        errors.append(
            f"scheme2 fused reduction "
            f"{head['scheme2_fused_reduction_m6']:.2f} < {SCHEME2_FLOOR} "
            "(>= p-fold at m=6)")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_traffic.json")
    ap.add_argument("--check-baseline", default=None)
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the (slower) kernel bit-identity checks")
    args = ap.parse_args(argv)

    cells = []
    for m, k, n in SHAPES:
        for p in PS:
            cell = run_cell(m, k, n, p, verify=not args.no_verify)
            cells.append(cell)
            r = cell["reduction"]
            hw = cell["projection"]["gpu"]["hardware"]
            print(f"({m},{k},{n}) p={p}: xla "
                  f"{cell['decomp_bytes']['xla']/1e6:.2f}MB -> prologue "
                  f"{r['prologue']:.2f}x, prepared(weight) "
                  f"{r['prepared_weight']:.2f}x, bit_identical="
                  f"{cell.get('bit_identical', 'skipped')}, proj "
                  f"H100 {hw['h100']['projected_tops']:.0f}/B200 "
                  f"{hw['b200']['projected_tops']:.0f} Top/s", flush=True)

    cells2 = []
    for m, k, n in SCHEME2_SHAPES:
        for p in MS:
            cell = run_scheme2_cell(m, k, n, p, verify=not args.no_verify)
            cells2.append(cell)
            r = cell["reduction"]
            hw = cell["projection"]["complex_3m"]["hardware"]
            bits = cell.get("bit_identical", {})
            print(f"scheme2 ({m},{k},{n}) m={p}: fused {r['prologue']:.2f}x"
                  f", prepared {r['prepared']:.2f}x, 3M "
                  f"{r['prologue_3m']:.2f}x, bit_identical="
                  f"{bits or 'skipped'}, vs ZGEMM H100 "
                  f"{hw['h100'].get('baseline_speedup', 0):.1f}x / B200 "
                  f"{hw['b200'].get('baseline_speedup', 0):.1f}x",
                  flush=True)

    cells_g = []
    for m, k, n in GUARD_SHAPES:
        cell = run_guard_cell(m, k, n)
        cells_g.append(cell)
        s1 = cell["schemes"]["ozaki1"]
        s2 = cell["schemes"]["ozaki2"]
        print(f"guard ({m},{k},{n}) r={GUARD_PROBES}: verify "
              f"{s1['verify_bytes_fused']/1e3:.1f}kB fused, overhead "
              f"s1 {100*s1['time_ratio']:.2f}%/s2 "
              f"{100*s2['time_ratio']:.2f}% time, "
              f"{100*s1['bytes_ratio']:.2f}%/"
              f"{100*s2['bytes_ratio']:.2f}% bytes", flush=True)

    cells_t = []
    for m, k, n in TELEMETRY_SHAPES:
        cell = run_telemetry_cell(m, k, n)
        cells_t.append(cell)
        s1 = cell["schemes"]["ozaki1"]
        s2 = cell["schemes"]["ozaki2"]
        print(f"telemetry ({m},{k},{n}): payload "
              f"{s1['telemetry_bytes']}B/call, overhead s1 "
              f"{100*s1['time_ratio']:.4f}%/s2 "
              f"{100*s2['time_ratio']:.4f}% time, "
              f"{100*s1['bytes_ratio']:.4f}%/"
              f"{100*s2['bytes_ratio']:.4f}% bytes", flush=True)
    tele_checks = telemetry_disabled_checks()
    print(f"telemetry disabled-mode: {tele_checks}", flush=True)

    cells_d = []
    for k, n in DECODE_SHAPES:
        cell = run_decode_cell(k, n, DECODE_P)
        cells_d.append(cell)
        b1 = cell["batches"]["1"]["per_token_bytes"]
        bmax = cell["batches"][str(max(DECODE_BATCHES))]["per_token_bytes"]
        print(f"decode (K={k},N={n}) p={DECODE_P}: prepared "
              f"{b1['prepared']/1e6:.2f}MB/token @b1 -> "
              f"{bmax['prepared']/1e6:.2f}MB/token @b{max(DECODE_BATCHES)} "
              f"({cell['amortization'][str(max(DECODE_BATCHES))]:.1f}x), "
              f"vs xla {cell['prepared_vs_xla']['1']:.1f}x", flush=True)

    cells_b = []
    batched_bits = {}
    if not args.no_verify:
        for scheme, p in BATCHED_SCHEMES:
            batched_bits[scheme] = _bit_identity_batched(scheme, p)
        print(f"batched bit-identity (fused vs vmapped 2-D): "
              f"{batched_bits}", flush=True)
    for m, k, n in SHAPES:
        for bsz in BATCHED_BS:
            for scheme, p in BATCHED_SCHEMES:
                cell = run_batched_cell(m, k, n, scheme, p, bsz)
                if batched_bits:
                    cell["bit_identical"] = batched_bits[scheme]
                cells_b.append(cell)
                hw = cell["projection"]["hardware"]
                print(f"batched ({m},{k},{n}) {scheme} p={p} B={bsz}: "
                      f"launches {cell['paths']['vmap']['launches']} -> 1, "
                      f"decomp {cell['decomp_reduction']:.2f}x, proj "
                      f"speedup H100 "
                      f"{hw['h100']['projected_speedup']:.2f}x", flush=True)

    cells_sh = []
    for m, k, n in SHARDED_SHAPES:
        for layout in MESH_LAYOUTS:
            cell = run_sharded_cell(m, k, n, SHARDED_P, layout)
            cells_sh.append(cell)
            col = cell["partitions"]["column"]
            row = cell["partitions"]["row"]
            print(f"sharded ({m},{k},{n}) p={SHARDED_P} "
                  f"mesh={cell['mesh']}: column "
                  f"{col['fused_bytes_per_shard']/1e6:.2f}MB/shard + "
                  f"{col['collective_bytes_per_device']/1e6:.2f}MB coll, "
                  f"row {row['fused_bytes_per_shard']/1e6:.2f}MB/shard + "
                  f"{row['collective_bytes_per_device']/1e6:.2f}MB coll "
                  f"(H100 eff {col['effective_tops']['h100']:.0f}/"
                  f"{row['effective_tops']['h100']:.0f} Top/s)", flush=True)

    p4 = [c for c in cells if c["p"] == 4]
    m6 = [c for c in cells2 if c["p"] == 6]
    report = {
        "schema": "bench_traffic/v7",
        "uses_per_step": USES,
        "cells": cells,
        "scheme2_cells": cells2,
        "batched_cells": cells_b,
        "sharded_cells": cells_sh,
        "guard_cells": cells_g,
        "telemetry_cells": cells_t,
        "decode_cells": cells_d,
        "acceptance": {
            "sharded_column_collective_free": all(
                c["partitions"]["column"]["collective_bytes_per_device"]
                == 0 for c in cells_sh),
            "prologue_reduction_p4":
                min(c["reduction"]["prologue"] for c in p4),
            "prepared_weight_reduction_p4":
                min(c["reduction"]["prepared_weight"] for c in p4),
            "bit_identical":
                all(c.get("bit_identical", True) for c in cells),
            "scheme2_fused_reduction_m6":
                min(c["reduction"]["prologue"] for c in m6),
            "scheme2_bit_identical":
                all(ok for c in cells2
                    for ok in c.get("bit_identical", {}).values()),
            "guard_overhead_max": max(
                sc[field] for c in cells_g for sc in c["schemes"].values()
                for field in ("bytes_ratio", "time_ratio")),
            "guard_overhead_ceiling": GUARD_OVERHEAD_CEILING,
            "telemetry_overhead_max": max(
                sc[field] for c in cells_t for sc in c["schemes"].values()
                for field in ("bytes_ratio", "time_ratio")),
            "telemetry_overhead_ceiling": TELEMETRY_OVERHEAD_CEILING,
            "telemetry_disabled_callback_free":
                tele_checks["callback_free_disabled"],
            "telemetry_disabled_bit_identical":
                tele_checks["bit_identical"],
            "decode_amortization_b32": min(
                c["amortization"][str(max(DECODE_BATCHES))]
                for c in cells_d),
            "decode_prepared_vs_xla": min(
                r for c in cells_d for r in c["prepared_vs_xla"].values()),
            "batched_launch_reduction_ok": all(
                c["launch_reduction"] >= c["batch"] for c in cells_b),
            "batched_decomp_reduction_min":
                min(c["decomp_reduction"] for c in cells_b),
            "batched_bit_identical":
                all(c.get("bit_identical", True) for c in cells_b),
        },
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}")

    if args.check_baseline:
        with open(args.check_baseline) as f:
            baseline = json.load(f)
        errors = check_baseline(report, baseline)
        for e in errors:
            print(f"REGRESSION: {e}", file=sys.stderr)
        if errors:
            return 1
        print("baseline check passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
