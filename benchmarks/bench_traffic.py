"""Decomposition-side HBM traffic: decompose-in-XLA vs fused prologue vs
PreparedOperand weight reuse.

Seeds the bench trajectory with a deterministic, interpret-mode-safe
metric: the analytic decomposition-byte model
(repro.core.traffic.scheme1_decomp_*_bytes, surfaced through
repro.utils.roofline.scheme1_decomposition_terms), corroborated by
measured compiled-HLO bytes/op-counts of the XLA-visible decomposition
stages, and a bit-identity check of the in-kernel prologue against the
split -> interleave -> kernel pipeline.

  PYTHONPATH=src python benchmarks/bench_traffic.py \
      [--out BENCH_traffic.json] [--check-baseline benchmarks/traffic_baseline.json]

With --check-baseline the run exits non-zero if any cell's decomposition
bytes regress above the recorded baseline or the headline reductions
fall below the acceptance floors (>=2x fused prologue, >=3x
PreparedOperand weight reuse at p=4) — the CI regression gate.
"""

from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import scheme1, traffic  # noqa: E402
from repro.core.precision import EmulationConfig  # noqa: E402
from repro.utils import roofline  # noqa: E402

SHAPES = [(256, 256, 256), (128, 384, 256), (256, 128, 512)]  # (M, K, N)
PS = (3, 4, 6)
USES = 3  # forward, remat re-forward, backward B^T — per layer per step
PROLOGUE_FLOOR = 2.0
PREPARED_FLOOR = 3.0


def _count_ops(hlo_text: str) -> int:
    return sum(1 for line in hlo_text.splitlines()
               if roofline._OP_RE.match(line))


def _measure(fn, *args) -> dict:
    """Compiled-HLO mem bytes + op count of a jitted stage (roofline path)."""
    text = jax.jit(fn).lower(*args).compile().as_text()
    stats = roofline.analyze_hlo(text)
    return {"mem_bytes": int(stats["mem_bytes"]), "ops": _count_ops(text)}


def _bit_identity(m: int, k: int, n: int, p: int) -> bool:
    """Prologue output must equal the split->interleave pipeline bitwise
    (same int8 slices -> same int32 accumulators -> same epilogue)."""
    from repro.kernels import ops
    rng = np.random.default_rng(p * 7919 + m + k + n)
    a = jnp.asarray(((rng.random((m, k)) - 0.5)
                     * np.exp(2.0 * rng.standard_normal((m, k))))
                    .astype(np.float32))
    b = jnp.asarray(((rng.random((k, n)) - 0.5)
                     * np.exp(2.0 * rng.standard_normal((k, n))))
                    .astype(np.float32))
    pro = ops.fused_scheme1_matmul(
        a, b, EmulationConfig(scheme="ozaki1", p=p, decomp="kernel"))
    xla = ops.fused_scheme1_matmul(
        a, b, EmulationConfig(scheme="ozaki1", p=p, decomp="xla"))
    return bool(jnp.array_equal(pro, xla))


def run_cell(m: int, k: int, n: int, p: int, verify: bool) -> dict:
    terms = roofline.scheme1_decomposition_terms(m, k, n, p, uses=USES)
    w = k * n  # the weight (rhs) operand
    weight = {
        "xla": traffic.scheme1_decomp_xla_bytes(w, p, USES),
        "prepared": traffic.scheme1_decomp_prepared_bytes(w, p, 1),
    }
    cell = {
        "m": m, "k": k, "n": n, "p": p,
        "decomp_bytes": {
            "xla": terms["xla_bytes"],
            "prologue": terms["prologue_bytes"],
            "prepared": terms["prepared_bytes"],
        },
        "weight_decomp_bytes": weight,
        "reduction": {
            "prologue": terms["xla_bytes"] / terms["prologue_bytes"],
            "prepared": terms["xla_bytes"] / terms["prepared_bytes"],
            "prepared_weight": weight["xla"] / weight["prepared"],
        },
    }

    beta = EmulationConfig(scheme="ozaki1", p=p).resolved_beta(k)

    def xla_stage(a, b):
        a_sl, mu = scheme1.split(a, p, beta, axis=1)
        b_sl, nu = scheme1.split(b, p, beta, axis=0)
        return (scheme1.interleave_k(a_sl, "a", 128),
                scheme1.interleave_k(b_sl, "b", 128), mu, nu)

    def prologue_stage(a, b):
        # Only the scale reductions stay in XLA on the prologue path.
        return (scheme1._pow2_row_scale(a, axis=1),
                scheme1._pow2_row_scale(b, axis=0))

    a_spec = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b_spec = jax.ShapeDtypeStruct((k, n), jnp.float32)
    cell["measured"] = {
        "xla_stage": _measure(xla_stage, a_spec, b_spec),
        "prologue_stage": _measure(prologue_stage, a_spec, b_spec),
    }
    # Per-backend roofline projection (paper Fig. 4/5 framing): fraction
    # of INT8 peak per hardware table of each registered kernel backend —
    # the 'gpu' entry carries both Hopper (H100) and Blackwell (B200).
    cell["projection"] = {
        bk: roofline.projected_throughput(m, k, n, p, backend=bk)
        for bk in ("tpu", "gpu")
    }
    if verify:
        cell["bit_identical"] = _bit_identity(m, k, n, p)
    return cell


def check_baseline(report: dict, baseline: dict) -> list[str]:
    errors = []
    base = {(c["m"], c["k"], c["n"], c["p"]): c for c in baseline["cells"]}
    for c in report["cells"]:
        key = (c["m"], c["k"], c["n"], c["p"])
        ref = base.get(key)
        if ref is None:
            continue
        for path, cur in c["decomp_bytes"].items():
            old = ref["decomp_bytes"].get(path)
            if old is not None and cur > old:
                errors.append(f"{key} {path}: {cur} > baseline {old}")
        if c.get("bit_identical") is False:
            errors.append(f"{key}: prologue not bit-identical to split")
    head = report["acceptance"]
    if head["prologue_reduction_p4"] < PROLOGUE_FLOOR:
        errors.append(f"prologue reduction {head['prologue_reduction_p4']:.2f}"
                      f" < {PROLOGUE_FLOOR}")
    if head["prepared_weight_reduction_p4"] < PREPARED_FLOOR:
        errors.append(
            f"prepared weight reduction "
            f"{head['prepared_weight_reduction_p4']:.2f} < {PREPARED_FLOOR}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_traffic.json")
    ap.add_argument("--check-baseline", default=None)
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the (slower) kernel bit-identity checks")
    args = ap.parse_args(argv)

    cells = []
    for m, k, n in SHAPES:
        for p in PS:
            cell = run_cell(m, k, n, p, verify=not args.no_verify)
            cells.append(cell)
            r = cell["reduction"]
            hw = cell["projection"]["gpu"]["hardware"]
            print(f"({m},{k},{n}) p={p}: xla "
                  f"{cell['decomp_bytes']['xla']/1e6:.2f}MB -> prologue "
                  f"{r['prologue']:.2f}x, prepared(weight) "
                  f"{r['prepared_weight']:.2f}x, bit_identical="
                  f"{cell.get('bit_identical', 'skipped')}, proj "
                  f"H100 {hw['h100']['projected_tops']:.0f}/B200 "
                  f"{hw['b200']['projected_tops']:.0f} Top/s", flush=True)

    p4 = [c for c in cells if c["p"] == 4]
    report = {
        "schema": "bench_traffic/v1",
        "uses_per_step": USES,
        "cells": cells,
        "acceptance": {
            "prologue_reduction_p4":
                min(c["reduction"]["prologue"] for c in p4),
            "prepared_weight_reduction_p4":
                min(c["reduction"]["prepared_weight"] for c in p4),
            "bit_identical":
                all(c.get("bit_identical", True) for c in cells),
        },
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}")

    if args.check_baseline:
        with open(args.check_baseline) as f:
            baseline = json.load(f)
        errors = check_baseline(report, baseline)
        for e in errors:
            print(f"REGRESSION: {e}", file=sys.stderr)
        if errors:
            return 1
        print("baseline check passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
