"""Sustained serving throughput: continuous batching vs the lockstep wave.

  PYTHONPATH=src python benchmarks/bench_serve.py \
      [--out BENCH_serve.json] [--check-baseline benchmarks/serve_baseline.json]

The cell a single-batch latency number cannot show (docs/serving.md):
a Poisson arrival trace of ragged requests (mixed prompt and generation
lengths) is served twice by the same jit-compiled step functions —

  * **continuous** — repro.serving.ContinuousEngine: a retired lane is
    refilled from the queue on the very next step, chunked prefill rides
    along with the running decodes;
  * **wave** — the same engine with ``wave_admission=True``, which
    reproduces the legacy lockstep schedule: a new cohort is admitted
    only after every lane of the previous one has drained, so stragglers
    decode at batch ~1 while finished lanes idle.

Reported per engine: sustained tokens/s (emitted tokens over the serve
loop's wall time, jit warmup excluded), TTFT p50, eviction count, and
the fraction of busy steps with a non-empty arrival queue.  With
--check-baseline the run exits non-zero unless (benchmarks/
serve_baseline.json gates):

  * continuous tokens/s beats the wave schedule by >= ``speedup_floor``;
  * the trace is heavy enough to measure sustained throughput — the
    queue is non-empty for >= ``queue_nonempty_min`` of the continuous
    engine's busy steps (ISSUE acceptance: >= 80% of steady state);
  * every request completes, and each request's token stream is
    bit-identical between the two schedules (per-lane row independence:
    batching changes throughput, never results).
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro import configs  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.serving import ContinuousEngine, Request  # noqa: E402

MAX_STEPS = 50_000   # runaway-loop backstop for either schedule


def build_trace(rng: np.random.Generator, vocab: int, requests: int,
                poisson: float) -> list[tuple[list[int], int, float]]:
    """(prompt, max_new_tokens, arrival) specs with ragged lengths: the
    raggedness is what the wave schedule pays for (stragglers decode
    alone) and the continuous schedule does not."""
    arrivals = (np.cumsum(rng.exponential(poisson, requests))
                if poisson > 0 else np.zeros(requests))
    return [(rng.integers(0, vocab, int(rng.integers(12, 25))).tolist(),
             int(rng.integers(4, 65)), float(arrivals[i]))
            for i in range(requests)]


def run_engine(arch, mesh, specs, wave: bool, args) -> dict:
    max_seq = max(len(p) + g for p, g, _ in specs)
    with mesh:
        eng = ContinuousEngine(
            arch, mesh, max_seq=max_seq, max_lanes=args.lanes,
            chunk=args.chunk, page_size=args.page_size,
            wave_admission=wave)
        # Warm both jit shapes (mixed prefill+decode and pure decode)
        # outside the timed window, then re-zero the clock so the
        # Poisson arrival offsets (and TTFT) are relative to serving
        # start, not to the multi-second compile.
        eng.run([Request(prompt=[1] * args.chunk, max_new_tokens=2,
                         arrival=0.0)])
        eng.reset_clock()
        u0 = eng.utilization()
        reqs = [Request(prompt=p, max_new_tokens=g, arrival=a)
                for p, g, a in specs]
        t0 = time.monotonic()
        results = eng.run(reqs, max_steps=MAX_STEPS)
        dt = time.monotonic() - t0
        u1 = eng.utilization()
    per_req = [results[r.rid] for r in reqs]
    tokens = sum(len(r.tokens) for r in per_req)
    busy = u1["busy_steps"] - u0["busy_steps"]
    nonempty = u1["queue_nonempty_steps"] - u0["queue_nonempty_steps"]
    ttfts = [r.ttft for r in per_req if r.ttft is not None]
    return {
        "schedule": "wave" if wave else "continuous",
        "tokens": tokens,
        "wall_s": dt,
        "tokens_per_s": tokens / dt,
        "steps": busy,
        "queue_nonempty_frac": nonempty / max(1, busy),
        "evictions": u1["evictions"] - u0["evictions"],
        "ttft_p50_s": float(np.median(ttfts)) if ttfts else None,
        "done": all(r.status == "done" for r in per_req),
        "page_high_water": u1["kv"]["high_water"],
        "token_streams": [r.tokens for r in per_req],
    }


def check_baseline(report: dict, baseline: dict) -> list[str]:
    gates = baseline["gates"]
    errors = []
    if report["speedup"] < gates["speedup_floor"]:
        errors.append(f"continuous/wave speedup {report['speedup']:.3f} "
                      f"< floor {gates['speedup_floor']}")
    frac = report["continuous"]["queue_nonempty_frac"]
    if frac < gates["queue_nonempty_min"]:
        errors.append(f"queue non-empty {frac:.2%} of busy steps "
                      f"< {gates['queue_nonempty_min']:.0%}: trace too "
                      "light to measure sustained throughput")
    if not report["bit_identical"]:
        errors.append("continuous token streams differ from the wave "
                      "reference: per-request row independence broken")
    for sched in ("continuous", "wave"):
        if not report[sched]["done"]:
            errors.append(f"{sched}: not every request completed")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--check-baseline", default=None)
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--poisson", type=float, default=0.02,
                    help="mean interarrival gap (s); the default keeps "
                         "the queue non-empty through steady state")
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = configs.get_smoke_config(args.arch)
    mesh = make_host_mesh()
    rng = np.random.default_rng(args.seed)
    specs = build_trace(rng, arch.model.vocab, args.requests, args.poisson)

    cells = {}
    for wave in (True, False):
        cell = run_engine(arch, mesh, specs, wave, args)
        cells[cell["schedule"]] = cell
        print(f"{cell['schedule']:>10}: {cell['tokens']} tokens in "
              f"{cell['wall_s']:.2f}s = {cell['tokens_per_s']:.1f} tok/s, "
              f"{cell['steps']} steps, queue non-empty "
              f"{cell['queue_nonempty_frac']:.0%}, ttft p50 "
              f"{cell['ttft_p50_s']:.3f}s, evictions "
              f"{cell['evictions']}", flush=True)

    bit_identical = (cells["continuous"]["token_streams"]
                     == cells["wave"]["token_streams"])
    speedup = (cells["continuous"]["tokens_per_s"]
               / cells["wave"]["tokens_per_s"])
    report = {
        "schema": "bench_serve/v1",
        "trace": {"arch": args.arch, "requests": args.requests,
                  "poisson": args.poisson, "lanes": args.lanes,
                  "chunk": args.chunk, "page_size": args.page_size,
                  "seed": args.seed},
        "continuous": {k: v for k, v in cells["continuous"].items()
                       if k != "token_streams"},
        "wave": {k: v for k, v in cells["wave"].items()
                 if k != "token_streams"},
        "speedup": speedup,
        "bit_identical": bit_identical,
    }
    print(f"continuous vs wave: {speedup:.2f}x, bit_identical="
          f"{bit_identical}")
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}")

    if args.check_baseline:
        with open(args.check_baseline) as f:
            baseline = json.load(f)
        errors = check_baseline(report, baseline)
        for e in errors:
            print(f"REGRESSION: {e}", file=sys.stderr)
        if errors:
            return 1
        print("baseline check passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
