"""Shared benchmark utilities.

CPU-container caveat (DESIGN.md §8): absolute Top/s are meaningless here;
what transfers to hardware is (a) the *relative* fused-vs-naive structure
gap (kernel-launch count + INT32 materialization), (b) the measured
effective precision, and (c) the analytical traffic/intensity columns
from the paper's Eqs. 9/10/14/15/17/18 — all of which these benchmarks
report side by side.
"""

from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall seconds of fn(*args) (blocked until ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def effective_tflops(n: int, seconds: float) -> float:
    """Paper Sec. V-B: 2N^3 reference workload / runtime."""
    return 2.0 * n ** 3 / seconds / 1e12


def bits_of_precision(out: np.ndarray, ref: np.ndarray) -> float:
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    return float(-np.log2(rel)) if rel > 0 else 60.0


def conditioned(rng, shape, phi=4.0, dtype=np.float32):
    """Paper Eq. 19 inputs with the paper's phi=4.0 conditioning."""
    return ((rng.random(shape) - 0.5)
            * np.exp(phi * rng.standard_normal(shape))).astype(dtype)


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
